"""Paper Table 2: ablation of pruning design choices @50% budget.

Rows: VP (full) / beam=3 / local (per-doc) pruning / step-size-3 /
non-iterative.  Claims validated: iterative >> non-iterative; global >=
local; step-3 ~ 3x faster with a small quality drop; beam: no gain at
~5x cost.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import metrics, voronoi
from repro.core.sampling import sample_sphere
from repro.serve.retrieval import TokenIndex, maxsim_scores


def _eval(index, q_emb, q_mask, rel):
    scores = maxsim_scores(index, q_emb, q_mask)
    return float(metrics.mrr_at_k(scores, rel, 10))


def run(budget: float = 0.5, n_samples: int = 2048):
    params = common.train_encoder(common.CFG_SPHERE)
    c, d_emb, d_mask, q_emb, q_mask = common.encode_all(params,
                                                        common.CFG_SPHERE)
    index = TokenIndex.build(d_emb, d_mask)
    samples = sample_sphere(jax.random.PRNGKey(1), n_samples,
                            d_emb.shape[-1])
    rows = []

    # full VP (global, iterative, step 1)
    def vp_full():
        ranks, errs, _ = voronoi.pruning_order_batch(d_emb, d_mask, samples)
        return voronoi.global_keep_masks(ranks, errs, d_mask, budget)

    t_full, keep = common.timeit(vp_full, repeat=1)
    rows.append(("voronoi_full", t_full,
                 _eval(index.with_keep(keep), q_emb, q_mask, c.rel)))

    # beam size 3 (document-level, then global merge is N/A -> local)
    n_keep = jnp.ceil(budget * d_mask.sum(1)).astype(jnp.int32)

    def vp_beam():
        def one(d, m, t):
            k, _ = voronoi.beam_pruning_order(d, m, samples, beam=3,
                                              target=common.CFG_SPHERE.doc_len // 2)
            return k
        return jax.vmap(one)(d_emb, d_mask, n_keep)

    t_beam, keep_b = common.timeit(vp_beam, repeat=1)
    rows.append(("beam_3", t_beam,
                 _eval(index.with_keep(keep_b), q_emb, q_mask, c.rel)))

    # local (per-document) pruning
    def vp_local():
        ranks, _, _ = voronoi.pruning_order_batch(d_emb, d_mask, samples)
        return jax.vmap(voronoi.keep_mask_from_order)(ranks, d_mask, n_keep)

    t_loc, keep_l = common.timeit(vp_local, repeat=1)
    rows.append(("local_pruning", t_loc,
                 _eval(index.with_keep(keep_l), q_emb, q_mask, c.rel)))

    # step size 3
    def vp_step3():
        ranks, errs, _ = voronoi.pruning_order_batch(d_emb, d_mask, samples,
                                                     step_size=3)
        return voronoi.global_keep_masks(ranks, errs, d_mask, budget)

    t_s3, keep_s3 = common.timeit(vp_step3, repeat=1)
    rows.append(("step_size_3", t_s3,
                 _eval(index.with_keep(keep_s3), q_emb, q_mask, c.rel)))

    # non-iterative (one-shot errors)
    def vp_oneshot():
        def one(d, m, t):
            errs = voronoi.estimate_errors(d, m, samples)
            order = jnp.argsort(jnp.where(m, errs, jnp.inf))
            rank = jnp.argsort(order)
            n_prune = jnp.maximum(m.sum() - t, 0)
            return m & (rank >= n_prune)
        return jax.vmap(one)(d_emb, d_mask, n_keep)

    t_os, keep_os = common.timeit(vp_oneshot, repeat=1)
    rows.append(("non_iterative", t_os,
                 _eval(index.with_keep(keep_os), q_emb, q_mask, c.rel)))
    return rows


def main():
    rows = run()
    by = {r[0]: r for r in rows}
    for name, t, mrr in rows:
        common.csv_line(f"table2/{name}", t * 1e6, f"mrr10={mrr:.4f}")
    common.csv_line(
        "table2/CLAIM_iterative_beats_noniterative", 0.0,
        f"holds={by['voronoi_full'][2] >= by['non_iterative'][2]}")
    common.csv_line(
        "table2/CLAIM_global_ge_local", 0.0,
        f"holds={by['voronoi_full'][2] >= by['local_pruning'][2] - 0.005}")
    common.csv_line(
        "table2/CLAIM_step3_faster", 0.0,
        f"holds={by['step_size_3'][1] < by['voronoi_full'][1]};"
        f"speedup={by['voronoi_full'][1] / max(by['step_size_3'][1], 1e-9):.2f}")
    common.csv_line(
        "table2/CLAIM_beam_no_gain", 0.0,
        f"holds={by['beam_3'][2] <= by['voronoi_full'][2] + 0.005}")


if __name__ == "__main__":
    main()
