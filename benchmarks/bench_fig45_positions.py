"""Paper Figs. 4+5: token-position analyses.

Fig. 4 analogue: per-position (i) frequency of being the max-dot-product
winner over sampled queries and (ii) aggregated mean error — the paper's
point is that mean error is much less position-skewed than win counts.
Fig. 5 analogue: distribution of normalized pruning rank by position
percentile (lower = pruned earlier).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import voronoi
from repro.core.sampling import sample_sphere


def run(n_samples=2048):
    params = common.train_encoder(common.CFG_SPHERE)
    c, d_emb, d_mask, q_emb, q_mask = common.encode_all(params,
                                                        common.CFG_SPHERE)
    samples = sample_sphere(jax.random.PRNGKey(3), n_samples,
                            d_emb.shape[-1])

    def contrib_and_err(d, m):
        st = voronoi.assign_cells(d, m, samples)
        wins = jnp.zeros((d.shape[0],)).at[st.bi].add(1.0) / n_samples
        errs = voronoi.token_errors(st, m, n_samples)
        return wins, jnp.where(m, errs, 0.0)

    wins, errs = jax.vmap(contrib_and_err)(d_emb, d_mask)
    ranks, _, _ = voronoi.pruning_order_batch(d_emb, d_mask, samples)

    m = d_emb.shape[1]
    n_real = d_mask.sum(1)
    pos_pct = (jnp.arange(m)[None, :] / jnp.maximum(n_real[:, None] - 1, 1))
    rank_pct = ranks / jnp.maximum(n_real[:, None] - 1, 1)

    bins = np.linspace(0, 1.0001, 6)
    rows = []
    pp = np.asarray(pos_pct)[np.asarray(d_mask)]
    ww = np.asarray(wins)[np.asarray(d_mask)]
    ee = np.asarray(errs)[np.asarray(d_mask)]
    rr = np.clip(np.asarray(rank_pct)[np.asarray(d_mask)], 0, 1)
    for i in range(5):
        sel = (pp >= bins[i]) & (pp < bins[i + 1])
        rows.append((f"pos_{i*20}_{(i+1)*20}", float(ww[sel].mean()),
                     float(ee[sel].mean()), float(np.median(rr[sel])),
                     float(np.quantile(rr[sel], 0.25)),
                     float(np.quantile(rr[sel], 0.75))))
    return rows


def main():
    rows = run()
    win_sk, err_sk = [], []
    for name, win, err, med, q25, q75 in rows:
        common.csv_line(f"fig45/{name}", 0.0,
                        f"win_freq={win:.5f};mean_err={err:.6f};"
                        f"rank_median={med:.3f};rank_iqr={q25:.3f}-{q75:.3f}")
        win_sk.append(win)
        err_sk.append(err)
    # skew = first-bin share relative to uniform share
    win_skew = win_sk[0] / max(sum(win_sk) / len(win_sk), 1e-9)
    err_skew = err_sk[0] / max(sum(err_sk) / len(err_sk), 1e-9)
    common.csv_line(
        "fig45/CLAIM_mean_error_less_skewed_than_wins", 0.0,
        f"holds={abs(err_skew - 1) <= abs(win_skew - 1) + 0.05};"
        f"win_skew={win_skew:.3f};err_skew={err_skew:.3f}")


if __name__ == "__main__":
    main()
