"""End-to-end training driver (deliverable b): train a ColBERT encoder
from scratch on the planted-relevance token corpus with the paper's
doc-sim regularizer, with checkpoint/restart fault tolerance.

The default runs a CPU-scale encoder for a few hundred steps.  Pass
--full to instantiate the paper's 12L/768 (~110M param) configuration —
the same code path, sized for a real accelerator.

Demonstrated:
  * in-batch contrastive MaxSim loss + alpha * L^(sim) (paper Eq. 10),
  * deterministic step-indexed pipeline with prefetch,
  * checkpoint every N steps + automatic resume (kill & rerun to test),
  * final eval: MRR@10 via two-stage retrieval, pre- vs post-pruning.

Run:  PYTHONPATH=src python examples/train_colbert.py [--steps 300]
"""

import argparse

import jax
import numpy as np

from repro import configs
from repro.core import metrics, voronoi
from repro.core.sampling import sample_sphere
from repro.data import synthetic
from repro.launch import train as train_driver
from repro.models import colbert as colbert_lib
from repro.serve.retrieval import TokenIndex, maxsim_scores


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full", action="store_true",
                    help="use the paper-scale 12L/768 config")
    ap.add_argument("--ckpt-dir", default="/tmp/colbert_example_ckpt")
    args = ap.parse_args()

    preset = "full" if args.full else "smoke"
    out = train_driver.run("colbert", preset=preset, steps=args.steps,
                           batch=8, ckpt_dir=args.ckpt_dir, ckpt_every=50,
                           lr=2e-3)
    print(f"trained to loss {out['final_loss']:.4f} in {out['wall_s']:.1f}s"
          f" (resumed from step {out['start']})")

    cfg = configs.get("colbert").smoke if not args.full else \
        configs.get("colbert").config
    params = out["state"]["params"]
    corpus = synthetic.token_corpus(0, n_docs=256, n_q=64, vocab=cfg.vocab,
                                    m=cfg.doc_len, l=cfg.query_len)
    d_emb, d_mask = colbert_lib.encode_docs(params, cfg, corpus.doc_ids)
    q_emb, q_mask = colbert_lib.encode_queries(params, cfg, corpus.q_ids)
    index = TokenIndex.build(np.asarray(d_emb, np.float32), d_mask)

    scores = maxsim_scores(index, q_emb, q_mask)
    mrr = float(metrics.mrr_at_k(scores, corpus.rel, 10))
    print(f"unpruned MRR@10 = {mrr:.4f}  ({index.storage()['tokens_kept']} "
          f"token vectors)")

    samples = sample_sphere(jax.random.PRNGKey(1), 2048, cfg.out_dim)
    ranks, errs, _ = voronoi.pruning_order_batch(
        jax.numpy.asarray(d_emb, jax.numpy.float32), d_mask, samples)
    keep = voronoi.global_keep_masks(ranks, errs, d_mask, 0.5)
    pruned = index.with_keep(keep)
    scores_p = maxsim_scores(pruned, q_emb, q_mask)
    mrr_p = float(metrics.mrr_at_k(scores_p, corpus.rel, 10))
    st = pruned.storage()
    print(f"VP @{st['remain_pct']:.0f}% MRR@10 = {mrr_p:.4f} "
          f"({st['tokens_kept']} token vectors, "
          f"{100 * mrr_p / max(mrr, 1e-9):.1f}% of unpruned)")


if __name__ == "__main__":
    main()
