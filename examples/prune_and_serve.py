"""Retrieval serving example: build -> prune -> serve batched requests.

Uses the embedding-level corpus (no training needed) to exercise the
serving stack: two-stage retrieval (pooled first stage + exact MaxSim
rerank), global Voronoi pruning at a byte budget chosen via the Mean
Error guidance of paper §6.4, and a batched RetrievalServer.

Run:  PYTHONPATH=src python examples/prune_and_serve.py
"""

import time

import jax
import jax.numpy as jnp

from repro.core import metrics, voronoi
from repro.core.sampling import sample_sphere
from repro.data import synthetic
from repro.serve.retrieval import RetrievalServer, TokenIndex, search


def main():
    c = synthetic.embedding_corpus(seed=3, n_docs=256, n_q=64, dim=24, m=40)
    index = TokenIndex.build(c.d_embs, c.d_masks)
    samples = sample_sphere(jax.random.PRNGKey(0), 4096, 24)
    ranks, errs, _ = voronoi.pruning_order_batch(c.d_embs, c.d_masks,
                                                 samples)

    # ME-guided budget selection (paper §6.4): largest pruning ratio whose
    # corpus mean error stays under a threshold.
    target_me = 0.02
    budget = None
    for frac in (0.2, 0.3, 0.4, 0.5, 0.6, 0.8):
        keep = voronoi.global_keep_masks(ranks, errs, c.d_masks, frac)
        me = float(voronoi.mean_error_batch(c.d_embs, c.d_masks, keep,
                                            samples).mean())
        print(f"budget {frac:.0%}: mean error {me:.4f}")
        if me <= target_me:
            budget = frac
            break
    budget = budget or 0.8
    keep = voronoi.global_keep_masks(ranks, errs, c.d_masks, budget)
    pruned = index.with_keep(keep)
    st = pruned.storage()
    print(f"selected budget {budget:.0%} -> {st['remain_pct']:.1f}% tokens, "
          f"{st['bytes_fp32'] / 1e6:.2f} MB (from "
          f"{st['bytes_fp32_unpruned'] / 1e6:.2f} MB)")

    # quality check: two-stage search on the pruned index
    _, _, full = search(pruned, c.q_embs, k=10, n_first=64)
    mrr = float(metrics.mrr_at_k(full, c.rel, 10))
    _, _, full0 = search(index, c.q_embs, k=10, n_first=64)
    mrr0 = float(metrics.mrr_at_k(full0, c.rel, 10))
    print(f"two-stage MRR@10: unpruned {mrr0:.4f} -> pruned {mrr:.4f}")

    # batched serving
    server = RetrievalServer(pruned, k=10, n_first=64)
    for batch_size in (8, 32, 64):
        q = c.q_embs[:batch_size]
        t0 = time.perf_counter()
        idx, scores = server.query_batch(q)
        dt = time.perf_counter() - t0
        print(f"batch {batch_size:>3}: {dt * 1e3:7.1f} ms total, "
              f"{dt / batch_size * 1e3:6.2f} ms/query, "
              f"top1 doc of q0 = {int(idx[0, 0])}")
    print("OK")


if __name__ == "__main__":
    main()
