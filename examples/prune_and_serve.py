"""Retrieval serving example: build -> prune -> pack -> save -> serve.

Uses the embedding-level corpus (no training needed) to exercise the
whole index lifecycle: two-stage retrieval (pooled first stage + exact
MaxSim rerank), global Voronoi pruning at a byte budget chosen via the
Mean Error guidance of paper §6.4, compaction into the packed serving
artifact (the step that turns the reported savings into actually-freed
bytes — optionally int8-compressed for ~4x more), a disk roundtrip
through repro.serve.index_io, a batched RetrievalServer over the
loaded artifact, and the live mutation lifecycle on the shipped
artifact: WAL-covered upsert + delete served from delta buckets
without restart, compaction into the next epoch (bit-identical
serving), and crash recovery of a torn write.

Run:  PYTHONPATH=src python examples/prune_and_serve.py
"""

import os
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.core import metrics, voronoi
from repro.core.sampling import sample_sphere
from repro.data import synthetic
from repro.serve import index_io, mutation
from repro.serve.retrieval import (RetrievalServer, TokenIndex, search,
                                   topk_search)


def main():
    c = synthetic.embedding_corpus(seed=3, n_docs=256, n_q=64, dim=24, m=40)
    index = TokenIndex.build(c.d_embs, c.d_masks)
    samples = sample_sphere(jax.random.PRNGKey(0), 4096, 24)
    ranks, errs, _ = voronoi.pruning_order_batch(c.d_embs, c.d_masks,
                                                 samples)

    # ME-guided budget selection (paper §6.4): largest pruning ratio whose
    # corpus mean error stays under a threshold.
    target_me = 0.02
    budget = None
    for frac in (0.2, 0.3, 0.4, 0.5, 0.6, 0.8):
        keep = voronoi.global_keep_masks(ranks, errs, c.d_masks, frac)
        me = float(voronoi.mean_error_batch(c.d_embs, c.d_masks, keep,
                                            samples).mean())
        print(f"budget {frac:.0%}: mean error {me:.4f}")
        if me <= target_me:
            budget = frac
            break
    budget = budget or 0.8
    keep = voronoi.global_keep_masks(ranks, errs, c.d_masks, budget)
    pruned = index.with_keep(keep)
    st = pruned.storage()
    print(f"selected budget {budget:.0%} -> {st['remain_pct']:.1f}% tokens, "
          f"{st['bytes_fp32'] / 1e6:.2f} MB (from "
          f"{st['bytes_fp32_unpruned'] / 1e6:.2f} MB) — reported only")

    # Compact: the packed artifact actually holds ~budget x the bytes.
    # Multiple-of-4 capacities instead of pow2: a few more compiled
    # shapes, much less padding at this mild (60%) budget.
    packed = pruned.pack(granularity=4, min_width=4)
    pst = packed.storage()
    print(f"packed: {pst['bytes_stored'] / 1e6:.2f} MB measured in "
          f"{pst['n_buckets']} buckets (cap_max {pst['cap_max']}, "
          f"{pst['padding_overhead']:.2f}x padding)")
    p8 = pruned.pack(granularity=4, min_width=4, compression="int8")
    print(f"packed int8: {p8.storage()['bytes_stored'] / 1e6:.2f} MB")

    # quality check: two-stage search, masked vs packed parity
    _, _, full = search(packed, c.q_embs, k=10, n_first=64)
    mrr = float(metrics.mrr_at_k(full, c.rel, 10))
    _, _, full_m = search(pruned, c.q_embs, k=10, n_first=64)
    mrr_m = float(metrics.mrr_at_k(full_m, c.rel, 10))
    _, _, full0 = search(index, c.q_embs, k=10, n_first=64)
    mrr0 = float(metrics.mrr_at_k(full0, c.rel, 10))
    print(f"two-stage MRR@10: unpruned {mrr0:.4f} -> pruned {mrr_m:.4f} "
          f"(masked) == {mrr:.4f} (packed)")

    # persistence roundtrip: serve the artifact a pruning job would ship
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "index")
        index_io.save_index(path, packed)
        loaded = index_io.load_index(path)
        print(f"saved + loaded packed index "
              f"({loaded.storage()['bytes_stored'] / 1e6:.2f} MB on disk "
              f"by layout)")

        # batched serving over the loaded artifact
        server = RetrievalServer(loaded, k=10, n_first=64)
        for batch_size in (8, 32, 64):
            q = c.q_embs[:batch_size]
            t0 = time.perf_counter()
            idx, scores = server.query_batch(q)
            dt = time.perf_counter() - t0
            print(f"batch {batch_size:>3}: {dt * 1e3:7.1f} ms total, "
                  f"{dt / batch_size * 1e3:6.2f} ms/query, "
                  f"top1 doc of q0 = {int(idx[0, 0])}")

        # --- live mutation lifecycle (DESIGN_BACKENDS.md §Mutation):
        # durable WAL-covered upsert + delete on the shipped artifact,
        # served from delta buckets without restart.
        fresh = jax.random.normal(jax.random.PRNGKey(7), (4, 40, 24))
        fmask = jnp.ones((4, 40), bool)
        ids = [5, 17, 256, 257]        # two updates, two brand-new docs
        delta = mutation.append_upsert(path, fresh, fmask, ids,
                                       granularity=4, min_width=4)
        mutation.append_delete(path, [9, 256])  # one old doc, one fresh
        log = mutation.load_state(path)
        server.apply_mutation(log.view())
        idx, scores = server.query_batch(c.q_embs[:8])
        print(f"live view (delta {delta}): {len(log.deltas)} delta leaf, "
              f"{len(log.tombstones)} tombstones, n_live={log.n_live}, "
              f"top1 doc of q0 = {int(idx[0, 0])}")
        # eager exact-route reference for the parity check below (the
        # server's whole-program jit may fuse the delta scorer with
        # 1-ulp different rounding than the eager composition, so the
        # bitwise law compares eager against eager)
        ref_idx, ref_scores = topk_search(server.index, c.q_embs[:8],
                                          k=10, mutation=log.view())

        # compact: fold the delta log into the next epoch beside the
        # live one — the root-manifest rename IS the swap, and the new
        # epoch serves bit-identically to the view it replaces
        compacted = mutation.Compactor(path, granularity=4,
                                       min_width=4).run()
        server.swap_index(index_io.load_index(path))
        # parity on the exact e2e route (the mutated view's route; the
        # server itself resumes its approximate two-stage default)
        idx2, scores2 = topk_search(server.index, c.q_embs[:8], k=10)
        same = bool(jnp.array_equal(ref_idx, idx2)
                    and jnp.array_equal(ref_scores, scores2))
        print(f"compacted to epoch {index_io.load_epoch(path)} "
              f"({len(compacted.buckets)} buckets): bit-identical "
              f"serving: {same}")

        # recover: a crash between WAL intent and commit leaves a torn
        # write; recover() rolls it back (or forward, if every covered
        # artifact write landed) and GCs orphans — idempotent
        index_io.wal_append(path, {"op": "compact", "seq": 99,
                                   "epoch": 2, "deltas": []})
        report = index_io.recover(path)
        print(f"recover after torn compact intent: {report}")
    print("OK")


if __name__ == "__main__":
    main()
