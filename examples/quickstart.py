"""Quickstart: Voronoi Pruning on a planted-relevance embedding corpus.

No training needed — documents are bags of token *vectors* with planted
topical structure, so you can see the paper's core mechanics in ~30s:

  1. build a token-level index,
  2. estimate per-token Voronoi-cell pruning errors (Eq. 8),
  3. iteratively prune to a 50% budget, corpus-wide (Alg. 1 + global),
  4. compare retrieval quality against random pruning at equal budget.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import baselines, metrics, voronoi
from repro.core.sampling import sample_sphere
from repro.data import synthetic
from repro.serve.retrieval import TokenIndex, maxsim_scores


def main():
    print("== Voronoi Pruning quickstart ==")
    c = synthetic.embedding_corpus(seed=0, n_docs=192, n_q=48, dim=24,
                                   m=32, stop_frac=0.5, noise=0.5,
                                   n_topics=24)
    index = TokenIndex.build(c.d_embs, c.d_masks)
    print(f"corpus: {index.storage()}")

    # Monte-Carlo sample the query sphere (Eq. 8)
    samples = sample_sphere(jax.random.PRNGKey(1), 4096, 24)

    # one document's error profile, for intuition
    errs = voronoi.estimate_errors(c.d_embs[0], c.d_masks[0], samples)
    real = errs[c.d_masks[0]]
    print(f"doc0 token errors: min={float(real.min()):.5f} "
          f"median={float(jnp.median(real)):.5f} "
          f"max={float(real.max()):.5f}")

    # corpus-level iterative pruning to 50%
    ranks, errs_all, _ = voronoi.pruning_order_batch(c.d_embs, c.d_masks,
                                                     samples)
    keep = voronoi.global_keep_masks(ranks, errs_all, c.d_masks, 0.5)
    pruned = index.with_keep(keep)
    print(f"pruned: {pruned.storage()}")

    def quality(idx, name):
        scores = maxsim_scores(idx, c.q_embs)
        mrr = float(metrics.mrr_at_k(scores, c.rel, 10))
        ndcg = float(metrics.ndcg_at_k(scores, c.gains, 10))
        print(f"{name:>16}: MRR@10={mrr:.4f}  nDCG@10={ndcg:.4f}")
        return ndcg

    m_full = quality(index, "unpruned")
    m_vp = quality(pruned, "voronoi @50%")
    keep_rnd = baselines.random_prune(jax.random.PRNGKey(2), c.d_masks, 0.5)
    m_rnd = quality(index.with_keep(keep_rnd), "random @50%")
    keep_fk = baselines.first_k(c.d_masks, 0.5)
    m_fk = quality(index.with_keep(keep_fk), "first-k @50%")

    print(f"\nVP keeps {100 * m_vp / m_full:.1f}% of unpruned nDCG at half "
          f"the storage (random keeps {100 * m_rnd / m_full:.1f}%, "
          f"first-k {100 * m_fk / m_full:.1f}%).")
    assert m_vp >= m_rnd, "Voronoi pruning should beat random pruning"
    assert m_vp >= m_fk, "Voronoi pruning should beat first-k pruning"
    print("OK")


if __name__ == "__main__":
    main()
