#!/usr/bin/env bash
# Tier-1 smoke: the exact ROADMAP verify command plus the kernel
# micro-benches (Pallas interpreter off-TPU), the backend-dispatch perf
# record, the throughput gates (fails if batched bucketed pruning
# regresses below the reference path, or packed serving below the
# masked path, at the bench shapes), and the packed-index lifecycle
# roundtrip (prune -> pack -> save on the first serve run, load -> query
# on the second — the offline/online split a real deployment uses).
# Run from anywhere; zstandard is optional (checkpointing falls back to
# uncompressed bodies).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

python -m pytest -x -q
python -m benchmarks.run kernels kernel_backends
python -m benchmarks.bench_kernel_backends --check

index_dir="$(mktemp -d)/packed_index"
trap 'rm -rf "$(dirname "$index_dir")"' EXIT
python -m repro.launch.serve --arch colbert --index-dir "$index_dir"
test -f "$index_dir/packed_index.json"
python -m repro.launch.serve --arch colbert --index-dir "$index_dir" \
  | grep -q "loaded packed index"
# sharded serving: load the same artifact and serve it over a 2-device
# candidates mesh on the e2e route (--n-first 0), so the query batch
# really runs the shard_map streaming merge, not just the banner.
XLA_FLAGS=--xla_force_host_platform_device_count=2 \
  python -m repro.launch.serve --arch colbert --index-dir "$index_dir" \
  --mesh host --n-first 0 \
  | grep -E "2 candidate shards|route: e2e" | wc -l | grep -q 2
echo "smoke OK"
