#!/usr/bin/env bash
# Tier-1 smoke: the exact ROADMAP verify command plus the kernel
# micro-benches (Pallas interpreter off-TPU), the backend-dispatch perf
# record, the throughput gates (fails if batched bucketed pruning
# regresses below the reference path, if packed serving drops below the
# masked path, if grid-placed serving loses parity/HLO cleanliness, if
# replicated failover loses bit-parity / degraded coverage breaks its
# 0 < c < 1 contract, or if crash recovery / compaction lose bit-parity
# with the live view, at the bench shapes), the kill -9 crash-recovery
# leg (a compaction SIGKILLed at a seed-randomized durability point,
# recovered, re-served bit-identically), and the packed-index lifecycle
# roundtrip (prune -> pack -> save on the first serve run, load ->
# query on the second — the offline/online split a real deployment
# uses), including a replicated run that kills a host group, a
# live-mutation run (upsert -> delete -> compact on the artifact), and
# a routed-serving run (build + persist the Voronoi-as-IVF routing
# sidecar, then reload it and serve the nprobe/bounded routes with a
# recall report against the exhaustive sweep).
# Run from anywhere; zstandard is optional (checkpointing falls back to
# uncompressed bodies).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

python -m pytest -x -q
python -m benchmarks.run kernels kernel_backends
python -m benchmarks.bench_kernel_backends --check

# 4-device grid parity subset (tests/_grid_cases.py, the same case
# bodies the test_placement.py subprocess fixtures run): every push
# exercises the multi-host merge-tree tier — per-group candidate
# reduction + cross-group exchange — bit-identical to the dense
# oracle, plus the fault-injection sweep (check_fault_tolerance /
# check_failover_server): kill-one-group under replicas=2 stays
# bit-identical, unreplicated loss degrades to the restricted oracle
# with explicit coverage, and all three --on-group-loss policies hold.
XLA_FLAGS=--xla_force_host_platform_device_count=4 \
  PYTHONPATH=src:tests${PYTHONPATH:+:$PYTHONPATH} \
  python -c "import _grid_cases; _grid_cases.main()" | grep -q GRID_CASES_OK

# crash-recovery leg (tests/_crash_cases.py, the same case bodies the
# test_mutation.py kill sweep runs): seed an artifact, upsert + delete
# through the WAL, then kill -9 a compaction child at a
# seed-randomized durability point, recover, and assert the re-served
# top-k is bit-identical to the uninterrupted lifecycle with zero
# orphaned files.  SMOKE_SEED rotates the crash point across runs.
SMOKE_SEED=${SMOKE_SEED:-$RANDOM} \
  PYTHONPATH=src:tests${PYTHONPATH:+:$PYTHONPATH} \
  python -c "import _crash_cases; _crash_cases.main()" \
  | grep -q CRASH_RECOVERY_OK

index_dir="$(mktemp -d)/packed_index"
trap 'rm -rf "$(dirname "$index_dir")"' EXIT
python -m repro.launch.serve --arch colbert --index-dir "$index_dir"
test -f "$index_dir/packed_index.json"
python -m repro.launch.serve --arch colbert --index-dir "$index_dir" \
  | grep "loaded packed index" > /dev/null  # no -q: read to EOF, no SIGPIPE race
# sharded serving: load the same artifact and serve it over a 2-device
# candidates mesh on the e2e route (--n-first 0), so the query batch
# really runs the shard_map streaming merge, not just the banner.
XLA_FLAGS=--xla_force_host_platform_device_count=2 \
  python -m repro.launch.serve --arch colbert --index-dir "$index_dir" \
  --mesh host --n-first 0 \
  | grep -E "2 candidate shards|route: e2e" | wc -l | grep -q 2
# grid placement lifecycle: sharded prune -> placement-split artifact
# (per-host-group sub-manifests) -> grid serving with the per-group
# merge + cross-group candidate exchange on a fresh 2x2 device grid.
grid_dir="$(dirname "$index_dir")/grid_index"
XLA_FLAGS=--xla_force_host_platform_device_count=4 \
  python -m repro.launch.serve --arch colbert --index-dir "$grid_dir" \
  --mesh grid --n-first 0 \
  | grep -E "host-group bodies|grid serving mesh|route: e2e" | wc -l \
  | grep -q 3
test -f "$grid_dir/packed_index.group0.json"
# fault-tolerant lifecycle: replicated (replicas=2) artifact on the
# same 2x2 grid, then serve it with host group 1 killed — the replica
# chains must absorb the loss at full coverage (the failover path the
# bench's --check above gates for bit-parity).
rep_dir="$(dirname "$index_dir")/replicated_index"
XLA_FLAGS=--xla_force_host_platform_device_count=4 \
  python -m repro.launch.serve --arch colbert --index-dir "$rep_dir" \
  --mesh grid --n-first 0 --replicas 2 --kill-group 1 \
  | grep -E "replicas=2|injected loss of host group 1|coverage: 1.000" \
  | wc -l | grep -q 3
test -f "$rep_dir/packed_index.group1.json"
# live-mutation lifecycle on the shipped artifact: durable upsert +
# delete through the WAL, served live from the delta-log view beside
# the base epoch, then compacted into epoch 1 — bit-identical serving
# (exact for the uncompressed smoke artifact) with zero orphans.
python -m repro.launch.serve --arch colbert --index-dir "$index_dir" \
  --upsert 4 --delete 1,3 --compact \
  | grep -E "serving live mutation view|post-compact parity: True.*orphans: 0" \
  | wc -l | grep -q 2
# routed serving lifecycle: first run builds + persists the routing
# sidecar beside the (freshly compacted) artifact and serves the
# nprobe route with a recall report against the exhaustive oracle;
# second run must LOAD the persisted table (Compactor keeps it fresh
# per epoch) and serve the provably-exact bounded route.
python -m repro.launch.serve --arch colbert --index-dir "$index_dir" \
  --route nprobe --nprobe 2 \
  | grep -E "built \+ saved routing table|routed \(nprobe\)|routed recall@10 vs exhaustive: 1.000" \
  | wc -l | grep -q 3
python -m repro.launch.serve --arch colbert --index-dir "$index_dir" \
  --route bounded \
  | grep -E "loaded routing table|routed recall@10 vs exhaustive: 1.000" \
  | wc -l | grep -q 2
echo "smoke OK"
