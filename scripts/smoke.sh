#!/usr/bin/env bash
# Tier-1 smoke: the exact ROADMAP verify command plus the kernel
# micro-benches (Pallas interpreter off-TPU), then the backend-dispatch
# perf record.  Run from anywhere; zstandard is optional (checkpointing
# falls back to uncompressed bodies).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

# Two test_sharded_exec failures predate this tooling (seed state, see
# ROADMAP Open items); deselect them so -x keeps its fail-fast value
# for everything else.  Remove the deselects when they are fixed.
python -m pytest -x -q \
  --deselect tests/test_sharded_exec.py::test_a2a_lookup_matches_dense_fwd_and_grad \
  --deselect tests/test_sharded_exec.py::test_sharded_lm_train_step_matches_single_device
python -m benchmarks.run kernels kernel_backends
echo "smoke OK"
