#!/usr/bin/env bash
# Tier-1 smoke: the exact ROADMAP verify command plus the kernel
# micro-benches (Pallas interpreter off-TPU), the backend-dispatch perf
# record, and the pruning-throughput gate (fails if batched bucketed
# pruning regresses below the reference path at the bench shape).
# Run from anywhere; zstandard is optional (checkpointing falls back to
# uncompressed bodies).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

python -m pytest -x -q
python -m benchmarks.run kernels kernel_backends
python -m benchmarks.bench_kernel_backends --check
echo "smoke OK"
